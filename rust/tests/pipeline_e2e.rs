//! End-to-end integration: the full coordinator pipeline over a real
//! multi-block model, both decode backends, plus evaluation — proving all
//! three layers compose (L3 pipeline → L2 artifact → L1 kernel).

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::{quantize_model, Workbench};
use ojbkq::data::SyntheticGrammar;
use ojbkq::eval::{perplexity, reasoning_accuracy, zero_shot_accuracy, ReasoningTask, ZeroShotTask};
use ojbkq::model::Model;
use ojbkq::quant::{Backend, Method, QuantConfig};
use ojbkq::rng::Rng;
use ojbkq::runtime::SolverRuntime;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    std::env::var("OJBKQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn tiny_setup() -> (Model, ojbkq::data::Corpus) {
    let cfg = ModelConfig {
        name: "e2e".into(),
        vocab_size: 64,
        d_model: 24,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        max_seq: 48,
    };
    let mut rng = Rng::new(0xE2E);
    let model = Model::random(cfg, &mut rng);
    let corpus = SyntheticGrammar::new(64, 0.2, 5).corpus(12_000, &mut rng);
    (model, corpus)
}

/// Every method end-to-end: quantize a 2-block model, evaluate ppl, and
/// check the quantized model stays close to FP for 4-bit.
#[test]
fn all_methods_full_pipeline_and_eval() {
    let (model, corpus) = tiny_setup();
    let fp_ppl = perplexity(&model, &corpus, 32, 640);
    for &method in Method::all() {
        let cfg = QuantConfig { ntile: 16, ..QuantConfig::paper_defaults(4, 8) };
        let (qm, report) =
            quantize_model(&model, &corpus, method, &cfg, 3, 32, None).expect("pipeline");
        let qppl = perplexity(&qm, &corpus, 32, 640);
        // 4-bit g8 on a tiny random model: ppl should stay in the same
        // ballpark (no blow-ups), and the pipeline must touch all layers.
        assert!(
            qppl < fp_ppl * 1.5 + 5.0,
            "{}: ppl exploded {qppl} vs fp {fp_ppl}",
            method.label()
        );
        if method != Method::Fp {
            assert_eq!(report.layers.len(), 14);
            assert!(report.compression_ratio() > 2.0);
        }
    }
}

/// The PJRT backend drives the same pipeline as the native backend and
/// produces an equivalent model (identical uniforms ⇒ near-identical
/// codes ⇒ near-identical ppl).
#[test]
fn pjrt_pipeline_matches_native_pipeline() {
    let dir = artifacts_dir();
    let rt = match SolverRuntime::new(&dir) {
        Ok(rt) if rt.select_variant(24, 16, 5).is_some() => rt,
        _ => {
            eprintln!("SKIP: no PJRT artifacts; run `make artifacts`");
            return;
        }
    };
    let (model, corpus) = tiny_setup();
    let base = QuantConfig { ntile: 16, ..QuantConfig::paper_defaults(4, 8) };
    let native_cfg = QuantConfig { backend: Backend::Native, ..base.clone() };
    let pjrt_cfg = QuantConfig { backend: Backend::Pjrt, ..base };
    let (qm_native, _) =
        quantize_model(&model, &corpus, Method::Ojbkq, &native_cfg, 3, 32, None).unwrap();
    let (qm_pjrt, _) =
        quantize_model(&model, &corpus, Method::Ojbkq, &pjrt_cfg, 3, 32, Some(&rt)).unwrap();
    let p_native = perplexity(&qm_native, &corpus, 32, 640);
    let p_pjrt = perplexity(&qm_pjrt, &corpus, 32, 640);
    let rel = (p_native - p_pjrt).abs() / p_native;
    assert!(rel < 0.02, "backend ppl mismatch: native {p_native} vs pjrt {p_pjrt}");
}

/// Zero-shot + reasoning evals run end-to-end on a quantized model.
#[test]
fn task_evals_run_on_quantized_model() {
    let (model, corpus) = tiny_setup();
    let cfg = QuantConfig { ntile: 16, ..QuantConfig::paper_defaults(3, 8) };
    let (qm, _) = quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 32, None).unwrap();
    for task in ZeroShotTask::suite().iter().take(2) {
        let acc = zero_shot_accuracy(&qm, &corpus, task, 20, 1);
        assert!((0.0..=100.0).contains(&acc));
    }
    let task = &ReasoningTask::suite()[0];
    let acc = reasoning_accuracy(&qm, &corpus, task, 10, 1);
    assert!((0.0..=100.0).contains(&acc));
}

/// Trained-artifact smoke: when `make artifacts` has produced trained
/// models, quantization must not catastrophically damage them at 4-bit
/// (Δppl small relative to FP) — the headline robustness claim.
#[test]
fn trained_model_4bit_quantization_is_gentle() {
    let dir = artifacts_dir();
    let wb = Workbench::load(&dir, "tiny-0.2M");
    if !wb.trained {
        eprintln!("SKIP: no trained artifacts for tiny-0.2M");
        return;
    }
    let fp = perplexity(&wb.model, &wb.corpus, wb.model.cfg.max_seq, 2048);
    let cfg = QuantConfig::paper_defaults(4, 128);
    let (qm, _) =
        quantize_model(&wb.model, &wb.corpus, Method::Ojbkq, &cfg, 8, 128, None).unwrap();
    let q = perplexity(&qm, &wb.corpus, wb.model.cfg.max_seq, 2048);
    assert!(
        q < fp * 1.10,
        "4-bit OJBKQ should cost <10% ppl on a trained tiny model: {q} vs {fp}"
    );
    assert!(q > fp * 0.90, "quantization should not 'improve' ppl by 10%: {q} vs {fp}");
}
