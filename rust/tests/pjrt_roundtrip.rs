//! Integration: the AOT Pallas artifact (PJRT backend) must agree with
//! the native Rust PPI decoder when fed identical inputs and uniforms.
//!
//! Skips (with a loud message) when `artifacts/` has no decoder variants
//! — run `make artifacts` first. The artifact dir can be overridden with
//! `OJBKQ_ARTIFACTS`.

use ojbkq::linalg::{cholesky_upper_jittered, syrk_upper};
use ojbkq::quant::klein::alpha_for;
use ojbkq::quant::ppi::{decode_tile, PpiInput};
use ojbkq::rng::Rng;
use ojbkq::runtime::SolverRuntime;
use ojbkq::tensor::Matrix;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    std::env::var("OJBKQ_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    })
}

fn runtime_or_skip(k: usize) -> Option<SolverRuntime> {
    let dir = artifacts_dir();
    match SolverRuntime::new(&dir) {
        Ok(rt) if rt.select_variant(1, 1, k).is_some() => Some(rt),
        Ok(_) => {
            eprintln!("SKIP: no k={k} decoder artifacts in {dir:?}; run `make artifacts`");
            None
        }
        Err(e) => {
            eprintln!("SKIP: PJRT runtime unavailable: {e}");
            None
        }
    }
}

struct Case {
    r: Matrix,
    s: Matrix,
    qbar: Matrix,
    alpha: Vec<f32>,
    uniforms: Vec<f32>,
}

fn make_case(m: usize, ntile: usize, k: usize, seed: u64) -> Case {
    let mut rng = Rng::new(seed);
    let a = Matrix::randn(2 * m + 2, m, 1.0, &mut rng);
    let g = syrk_upper(&a, 0.05);
    let (r, _) = cholesky_upper_jittered(&g, 1e-6).unwrap();
    let s = Matrix::from_fn(m, ntile, |_, _| 0.05 + 0.2 * rng.uniform_f32());
    let qbar = Matrix::from_fn(m, ntile, |_, _| 15.0 * rng.uniform_f32());
    let alpha: Vec<f32> = (0..ntile)
        .map(|j| {
            let min_rbar_sq = (0..m)
                .map(|i| {
                    let v = r.get(i, i) as f64 * s.get(i, j) as f64;
                    v * v
                })
                .fold(f64::INFINITY, f64::min);
            alpha_for(k.max(2), m, min_rbar_sq) as f32
        })
        .collect();
    let uniforms = rng.uniform_vec_f32((k + 1) * m * ntile);
    Case { r, s, qbar, alpha, uniforms }
}

/// Greedy decode (k=0) must match bit-exactly: it is pure rounding of
/// identical f32 back-substitution chains (tolerate a vanishing number of
/// boundary flips from non-associative float reductions).
#[test]
fn greedy_pjrt_matches_native() {
    let Some(rt) = runtime_or_skip(0) else { return };
    for &(m, ntile, qmax) in &[(48usize, 32usize, 15.0f32), (64, 64, 7.0), (100, 17, 15.0)] {
        let c = make_case(m, ntile, 0, 100 + m as u64);
        let native = decode_tile(&PpiInput {
            r: &c.r,
            s: &c.s,
            qbar: &c.qbar,
            qmax,
            k: 0,
            block: 16,
            alpha: &c.alpha,
            uniforms: &c.uniforms,
        });
        let pjrt = rt
            .decode_tile(&c.r, &c.s, &c.qbar, qmax, 0, &c.alpha, &c.uniforms)
            .expect("pjrt decode");
        let total = (m * ntile) as f64;
        let mismatches = native
            .q
            .as_slice()
            .iter()
            .zip(pjrt.as_slice())
            .filter(|(a, b)| a != b)
            .count();
        assert!(
            (mismatches as f64) / total < 0.005,
            "m={m} ntile={ntile}: {mismatches}/{total} codes differ"
        );
    }
}

/// Sampled paths consume the SAME uniforms in the same order, so the
/// K-best winner should agree up to rare boundary flips.
#[test]
fn sampled_pjrt_matches_native() {
    let k = 5usize;
    let Some(rt) = runtime_or_skip(k) else { return };
    let (m, ntile, qmax) = (64usize, 48usize, 15.0f32);
    let c = make_case(m, ntile, k, 7);
    let native = decode_tile(&PpiInput {
        r: &c.r,
        s: &c.s,
        qbar: &c.qbar,
        qmax,
        k,
        block: 16,
        alpha: &c.alpha,
        uniforms: &c.uniforms,
    });
    let pjrt = rt
        .decode_tile(&c.r, &c.s, &c.qbar, qmax, k, &c.alpha, &c.uniforms)
        .expect("pjrt decode");
    let total = (m * ntile) as f64;
    let mismatches = native
        .q
        .as_slice()
        .iter()
        .zip(pjrt.as_slice())
        .filter(|(a, b)| a != b)
        .count();
    assert!(
        (mismatches as f64) / total < 0.01,
        "{mismatches}/{total} codes differ between native and pjrt"
    );
}

/// Padding path: request a tile smaller than any registered variant.
#[test]
fn padded_tile_pjrt_matches_native() {
    let Some(rt) = runtime_or_skip(0) else { return };
    let (m, ntile, qmax) = (33usize, 9usize, 15.0f32);
    let c = make_case(m, ntile, 0, 11);
    let native = decode_tile(&PpiInput {
        r: &c.r,
        s: &c.s,
        qbar: &c.qbar,
        qmax,
        k: 0,
        block: 16,
        alpha: &c.alpha,
        uniforms: &c.uniforms,
    });
    let pjrt = rt
        .decode_tile(&c.r, &c.s, &c.qbar, qmax, 0, &c.alpha, &c.uniforms)
        .expect("pjrt decode");
    assert_eq!(pjrt.shape(), (m, ntile));
    let mismatches = native
        .q
        .as_slice()
        .iter()
        .zip(pjrt.as_slice())
        .filter(|(a, b)| a != b)
        .count();
    assert!((mismatches as f64) / ((m * ntile) as f64) < 0.005, "{mismatches} mismatches");
}
