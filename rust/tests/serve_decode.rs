//! Serving-engine parity and scheduler invariants (PR 8).
//!
//! The contract under test: KV-cached decode is **bit-identical** to the
//! teacher-forced forward pass at every position — on every deployment
//! width, on both packed kernel cores, on the dense-exec splice, and at
//! any thread count — and the continuous-batching scheduler never
//! changes a sequence's tokens (batched ≡ single-stream) nor lets a
//! retired request generate past its budget.

use ojbkq::config::ModelConfig;
use ojbkq::infer::{set_packed_core_override, PackedCore, PackedLinear, QuantizedModel};
use ojbkq::model::{LanguageModel, Model};
use ojbkq::quant::{rtn, QuantConfig};
use ojbkq::rng::Rng;
use ojbkq::serve::{DecodeScratch, Request, Scheduler, ServeEngine};
use ojbkq::tensor::Matrix;
use ojbkq::util::argmax;
use std::sync::Mutex;

/// Serializes tests that flip the process-global core/thread overrides.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

/// Tiny RTN-packed serving model (`packed = false` → dense f32 splice).
fn serve_model(wbit: u8, packed: bool) -> QuantizedModel {
    let cfg = ModelConfig {
        name: format!("serve-w{wbit}"),
        vocab_size: 48,
        d_model: 24,
        n_layers: 2,
        n_heads: 3,
        d_ff: 32,
        max_seq: 32,
    };
    let mut rng = Rng::new(0x5E12 + wbit as u64);
    let m = Model::random(cfg, &mut rng);
    let mut qm = QuantizedModel::from_model(&m);
    let qc = QuantConfig { wbit, group_size: 8, ..Default::default() };
    for id in qm.linear_ids() {
        let q = rtn::quantize(m.linear(id), &qc);
        qm.set_layer(id, PackedLinear::from_quantized(&q, packed));
    }
    qm
}

/// Greedy serve loop driven straight on the engine: prefill + `n_new`
/// decode steps. Returns (per-step logits rows, prefill logits, final
/// token stream).
fn greedy_serve(
    qm: &QuantizedModel,
    prompt: &[u16],
    n_new: usize,
) -> (Vec<Vec<f32>>, Matrix, Vec<u16>) {
    let engine = ServeEngine::new(qm);
    let mut caches = engine.new_caches(prompt.len() + n_new);
    let mut scratch = DecodeScratch::new(&qm.cfg);
    let prefill = engine.prefill(prompt, &mut caches);
    let mut tokens = prompt.to_vec();
    let mut next = argmax(prefill.row(prefill.rows() - 1)) as u16;
    let mut rows = Vec::new();
    for _ in 0..n_new {
        tokens.push(next);
        let row = engine.decode_step(next, tokens.len() - 1, &mut caches, &mut scratch).to_vec();
        next = argmax(&row) as u16;
        rows.push(row);
    }
    (rows, prefill, tokens)
}

/// Bit-exact check of the whole serve surface against the teacher-forced
/// forward pass over the final token stream.
fn assert_serve_matches_forward(qm: &QuantizedModel, prompt: &[u16], n_new: usize, what: &str) {
    let (rows, prefill, tokens) = greedy_serve(qm, prompt, n_new);
    let full = qm.forward(&tokens);
    for pos in 0..prompt.len() {
        assert_eq!(prefill.row(pos), full.row(pos), "{what}: prefill position {pos}");
    }
    for (i, row) in rows.iter().enumerate() {
        let pos = prompt.len() + i;
        assert_eq!(&row[..], full.row(pos), "{what}: decode position {pos}");
    }
}

/// Decode ≡ teacher-forced forward at every deployment width, across
/// ragged prompt lengths (including a single-token prompt).
#[test]
fn decode_matches_forward_across_widths_and_prompt_lengths() {
    for &wbit in &[2u8, 3, 4] {
        let qm = serve_model(wbit, true);
        for prompt in [vec![5u16], vec![7, 2, 9, 1, 4], vec![3; 9]] {
            let what = format!("w{wbit} prompt_len={}", prompt.len());
            assert_serve_matches_forward(&qm, &prompt, 5, &what);
        }
    }
}

/// The same parity holds under both packed kernel cores (the integer
/// default and the f32 parity reference), flipped via the same
/// process-global override the CLI's `--f32-core` uses.
#[test]
fn decode_matches_forward_on_both_packed_cores() {
    let _g = OVERRIDE_LOCK.lock().unwrap();
    let qm = serve_model(4, true);
    for core in [PackedCore::Int, PackedCore::F32] {
        set_packed_core_override(Some(core));
        assert_serve_matches_forward(&qm, &[11, 3, 8, 30], 5, &format!("{core:?}"));
    }
    set_packed_core_override(None);
}

/// The dense-exec splice (`PackedLinear::Dense`) routes decode through
/// `row_matmul_into` — still bit-identical to its batch `matmul`.
#[test]
fn decode_matches_forward_on_dense_exec_leg() {
    let qm = serve_model(4, false);
    assert_serve_matches_forward(&qm, &[1, 44, 17, 6, 22, 9], 5, "dense splice");
}

/// Decode logits are bit-stable across thread pins — the packed grid
/// accumulates exactly in i32 and the batched attention fan-out is
/// per-sequence, so threading never moves a bit.
#[test]
fn decode_is_bit_stable_across_thread_counts() {
    let _g = OVERRIDE_LOCK.lock().unwrap();
    let qm = serve_model(3, true);
    let prompt: Vec<u16> = vec![9, 27, 5, 13];
    ojbkq::parallel::set_thread_override(1);
    let (base_rows, base_prefill, base_tokens) = greedy_serve(&qm, &prompt, 6);
    for threads in [2usize, 4] {
        ojbkq::parallel::set_thread_override(threads);
        let (rows, prefill, tokens) = greedy_serve(&qm, &prompt, 6);
        assert_eq!(tokens, base_tokens, "{threads} threads: token stream moved");
        assert_eq!(rows, base_rows, "{threads} threads: decode logits moved");
        for pos in 0..prompt.len() {
            assert_eq!(prefill.row(pos), base_prefill.row(pos), "{threads} threads: prefill");
        }
    }
    ojbkq::parallel::set_thread_override(0);
}

/// Engine-level batched decode ≡ per-sequence single-stream decode,
/// bit-exact, on ragged positions (each sequence at a different cache
/// length).
#[test]
fn batched_decode_step_matches_single_stream() {
    let qm = serve_model(4, true);
    let engine = ServeEngine::new(&qm);
    let prompts: [&[u16]; 3] = [&[4, 9], &[1, 2, 3, 4, 5], &[40, 7, 33]];
    let n_new = 4;
    // Single-stream leg.
    let mut scratch = DecodeScratch::new(&qm.cfg);
    let mut single_rows: Vec<Vec<Vec<f32>>> = Vec::new();
    for p in prompts {
        let mut caches = engine.new_caches(p.len() + n_new);
        let prefill = engine.prefill(p, &mut caches);
        let mut tokens = p.to_vec();
        let mut next = argmax(prefill.row(prefill.rows() - 1)) as u16;
        let mut rows = Vec::new();
        for _ in 0..n_new {
            tokens.push(next);
            let row =
                engine.decode_step(next, tokens.len() - 1, &mut caches, &mut scratch).to_vec();
            next = argmax(&row) as u16;
            rows.push(row);
        }
        single_rows.push(rows);
    }
    // Batched leg: same prompts prefilled, then advanced in lockstep.
    let mut all_caches: Vec<Vec<_>> = Vec::new();
    let mut tokens: Vec<Vec<u16>> = Vec::new();
    for p in prompts {
        let mut caches = engine.new_caches(p.len() + n_new);
        let prefill = engine.prefill(p, &mut caches);
        let mut t = p.to_vec();
        t.push(argmax(prefill.row(prefill.rows() - 1)) as u16);
        all_caches.push(caches);
        tokens.push(t);
    }
    for step in 0..n_new {
        let inputs: Vec<(u16, usize)> =
            tokens.iter().map(|t| (*t.last().unwrap(), t.len() - 1)).collect();
        let mut cs: Vec<&mut [_]> = all_caches.iter_mut().map(|c| c.as_mut_slice()).collect();
        let logits = engine.decode_step_batch(&inputs, &mut cs);
        for (r, t) in tokens.iter_mut().enumerate() {
            assert_eq!(
                logits.row(r),
                &single_rows[r][step][..],
                "seq {r} step {step}: batched logits diverge from single-stream"
            );
            t.push(argmax(logits.row(r)) as u16);
        }
    }
}

/// Scheduler end-to-end: batched continuous serving produces exactly the
/// tokens single-stream serving does, request by request.
#[test]
fn scheduler_batched_matches_single_stream() {
    let qm = serve_model(4, true);
    let run = |max_concurrent: usize| {
        let mut sched = Scheduler::new(&qm, max_concurrent);
        for (i, prompt) in
            [vec![4u16, 9], vec![1, 2, 3, 4, 5], vec![40, 7, 33], vec![12]].into_iter().enumerate()
        {
            sched
                .submit(Request { id: i as u64, prompt, max_new: 3 + i, temperature: 0.0, seed: 0 })
                .expect("admitted");
        }
        let mut fins = sched.run().to_vec();
        fins.sort_by_key(|f| f.id);
        fins.iter().map(|f| f.generated.clone()).collect::<Vec<_>>()
    };
    let single = run(1);
    for conc in [2usize, 3, 4] {
        assert_eq!(run(conc), single, "max_concurrent={conc} changed generated tokens");
    }
}

/// Retirement invariant: every request generates **exactly** its
/// (clamped) budget and not one token more — a retired sequence never
/// re-enters a batch. Budgets differ so retirements interleave with
/// live decoding, and one prompt sits at `max_seq` (clamped budget 0).
#[test]
fn retired_requests_generate_exactly_their_budget() {
    let qm = serve_model(4, true);
    let max_seq = qm.cfg.max_seq;
    let mut sched = Scheduler::new(&qm, 3);
    let budgets = [2usize, 6, 9, 4];
    for (i, &b) in budgets.iter().enumerate() {
        sched
            .submit(Request {
                id: i as u64,
                prompt: vec![(3 + i) as u16; 2 + i],
                max_new: b,
                temperature: 0.0,
                seed: 0,
            })
            .expect("admitted");
    }
    // Prompt already at max_seq: admitted, clamped to 0 new tokens,
    // retired without ever touching the engine.
    sched
        .submit(Request { id: 99, prompt: vec![5; max_seq], max_new: 8, temperature: 0.0, seed: 0 })
        .expect("admitted");
    let fins = sched.run().to_vec();
    assert_eq!(fins.len(), budgets.len() + 1);
    let total: usize = budgets.iter().sum();
    assert_eq!(sched.tokens_generated(), total as u64);
    assert_eq!(sched.active_len(), 0);
    assert_eq!(sched.pending_len(), 0);
    for f in &fins {
        if f.id == 99 {
            assert!(f.generated.is_empty(), "clamped request must generate nothing");
            assert_eq!(f.kv_bytes, 0);
        } else {
            assert_eq!(
                f.generated.len(),
                budgets[f.id as usize],
                "request {} overshot or undershot its budget",
                f.id
            );
            assert!(f.kv_bytes > 0);
        }
    }
}
