//! Convergence + round-trip battery for the iterative solver families
//! (QuantEase, ADMM-Q) on the shared-factor engine.
//!
//! The contract under test (DESIGN.md §Solver families):
//!
//! * the per-sweep / per-iteration objective trace is monotonically
//!   non-increasing — by construction (f64 descent guard in QuantEase,
//!   incumbent reporting in ADMM-Q), so the assertions are strict;
//! * the Babai/Klein warm start is never worse than RTN initialization,
//!   and the refined solution is never worse than either init;
//! * codes are bit-identical across `OJBKQ_THREADS ∈ {1, 4}` (columns
//!   are tile-parallel, each column's coordinate loop is serial f64);
//! * both families run end-to-end through `quantize_model` and survive
//!   an OJBQ1 save→load→forward round trip bit-identically.
//!
//! Thread pinning goes through [`with_threads`] (programmatic override +
//! file-wide mutex), same idiom as `solver_parallel.rs`.

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::quantize_model;
use ojbkq::data::SyntheticGrammar;
use ojbkq::infer::{load_quantized, save_quantized};
use ojbkq::model::{LanguageModel, Model};
use ojbkq::parallel::set_thread_override;
use ojbkq::quant::{admmq, quantease, IterStats, Method, QuantConfig, QuantizedLinear};
use ojbkq::rng::Rng;
use ojbkq::tensor::Matrix;
use std::sync::Mutex;

static PIN_LOCK: Mutex<()> = Mutex::new(());

fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let _guard = PIN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_thread_override(n);
    let out = f();
    set_thread_override(0);
    out
}

fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let x_fp = Matrix::randn(p, m, 1.0, &mut rng);
    let noise = Matrix::randn(p, m, 0.05, &mut rng);
    let x_rt = x_fp.add(&noise);
    (w, x_fp, x_rt)
}

const FAMILIES: [Method; 2] = [Method::QuantEase, Method::AdmmQ];

/// Run one iterative family on a layer with an owned factor.
fn solve(
    method: Method,
    w: &Matrix,
    x_fp: &Matrix,
    x_rt: &Matrix,
    cfg: &QuantConfig,
    seed: u64,
) -> (QuantizedLinear, IterStats) {
    let mut rng = Rng::new(seed);
    match method {
        Method::QuantEase => {
            quantease::quantize_with(w, x_fp, x_rt, cfg, &mut rng, None, None).unwrap()
        }
        Method::AdmmQ => admmq::quantize_with(w, x_fp, x_rt, cfg, &mut rng, None, None).unwrap(),
        other => unreachable!("not an iterative family: {other:?}"),
    }
}

#[test]
fn objective_trace_is_monotone_non_increasing() {
    let (w, x_fp, x_rt) = layer(40, 32, 96, 0xF1);
    let cfg = QuantConfig {
        wbit: 3,
        group_size: 16,
        k: 5,
        ntile: 16,
        mu: 0.5,
        lambda: 0.3,
        ..Default::default()
    };
    for method in FAMILIES {
        let (_, it) = solve(method, &w, &x_fp, &x_rt, &cfg, 7);
        assert!(!it.obj_trace.is_empty(), "{method:?}: empty trace");
        for pair in it.obj_trace.windows(2) {
            assert!(
                pair[1] <= pair[0],
                "{method:?}: objective increased within the trace: {} -> {}",
                pair[0],
                pair[1]
            );
        }
        assert!(
            it.final_obj() <= it.init_obj,
            "{method:?}: final objective above init ({} > {})",
            it.final_obj(),
            it.init_obj
        );
        // The proxy residual f(q) − f(w_real) is a norm — nonnegative up
        // to f64 accumulation noise — and refinement shrank it.
        assert!(it.resid() >= -1e-6, "{method:?}: negative residual {}", it.resid());
        assert!(it.resid() <= it.init_resid() + 1e-9, "{method:?}: refinement hurt");
    }
}

#[test]
fn warm_start_never_worse_than_rtn_init() {
    let cfg = QuantConfig {
        wbit: 4,
        group_size: 8,
        k: 3,
        ntile: 12,
        mu: 0.4,
        lambda: 0.25,
        ..Default::default()
    };
    for seed in [0xF2u64, 0xF3, 0xF4] {
        let (w, x_fp, x_rt) = layer(32, 24, 80, seed);
        for method in FAMILIES {
            let (_, it) = solve(method, &w, &x_fp, &x_rt, &cfg, seed ^ 0x55);
            // Per-column best-of-{Babai warm start, RTN} initialization
            // makes the combined init at least as good as either
            // candidate, and the refined solution at least as good as
            // the init — both exact, not approximate, guarantees.
            assert!(
                it.init_obj <= it.rtn_obj + 1e-9,
                "{method:?} seed {seed:#x}: init worse than RTN ({} > {})",
                it.init_obj,
                it.rtn_obj
            );
            assert!(
                it.init_obj <= it.warm_obj + 1e-9,
                "{method:?} seed {seed:#x}: init worse than warm start"
            );
            assert!(
                it.final_obj() <= it.rtn_obj + 1e-9,
                "{method:?} seed {seed:#x}: refined solution worse than RTN init ({} > {})",
                it.final_obj(),
                it.rtn_obj
            );
            assert!(
                it.final_obj() <= it.warm_obj + 1e-9,
                "{method:?} seed {seed:#x}: refined solution worse than Babai warm start"
            );
        }
    }
}

#[test]
fn codes_bit_identical_across_thread_counts() {
    let (w, x_fp, x_rt) = layer(48, 40, 96, 0xF5);
    for method in FAMILIES {
        for &ntile in &[5usize, 16, 40] {
            let cfg = QuantConfig {
                wbit: 3,
                group_size: 16,
                k: 5,
                ntile,
                mu: 0.5,
                lambda: 0.3,
                ..Default::default()
            };
            let run = |threads: usize| {
                with_threads(threads, || solve(method, &w, &x_fp, &x_rt, &cfg, 11))
            };
            let (q1, it1) = run(1);
            let (q4, it4) = run(4);
            assert_eq!(q1.codes, q4.codes, "{method:?} ntile={ntile}: codes diverged");
            assert_eq!(
                q1.dequantize().as_slice(),
                q4.dequantize().as_slice(),
                "{method:?} ntile={ntile}: effective weight diverged"
            );
            assert_eq!(it1, it4, "{method:?} ntile={ntile}: convergence stats diverged");
        }
    }
}

#[test]
fn ojbq1_roundtrip_and_end_to_end_pipeline() {
    // Both families through the full pipeline (captures, shared group
    // factors, packed serialization) and back off disk.
    let cfg_model = ModelConfig {
        name: "fam".into(),
        vocab_size: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
    };
    let mut rng = Rng::new(3);
    let model = Model::random(cfg_model, &mut rng);
    let corpus = SyntheticGrammar::new(32, 0.2, 5).corpus(6_000, &mut rng);
    let cfg = QuantConfig { wbit: 4, group_size: 8, k: 3, ntile: 8, ..Default::default() };
    let dir = std::env::temp_dir().join("ojbkq_solver_families");
    std::fs::create_dir_all(&dir).unwrap();
    for method in FAMILIES {
        let (qm, report) = quantize_model(&model, &corpus, method, &cfg, 3, 16, None)
            .unwrap_or_else(|e| panic!("{method:?} pipeline failed: {e:#}"));
        assert_eq!(report.method, method.label(), "{method:?}: report label");
        assert!(!report.layers.is_empty(), "{method:?}: no layers quantized");
        let path = dir.join(format!("rt_{}.ojbq1", method.label().to_ascii_lowercase()));
        save_quantized(&qm, &path).unwrap();
        let back = load_quantized(&path, "fam").unwrap();
        for toks in [vec![2u16, 4, 6, 8, 1], vec![31, 0, 7, 7, 2, 19]] {
            assert_eq!(
                back.forward(&toks),
                qm.forward(&toks),
                "{method:?}: reloaded forward diverged"
            );
        }
        std::fs::remove_file(&path).unwrap();
    }
}
