//! Parity suite for the parallel shared-factor layer-solve engine.
//!
//! The engine's contract is that neither axis of restructuring changes a
//! single bit of solver output:
//!
//! * **parallel vs serial** — the tile-parallel Random-K decode
//!   (`OJBKQ_THREADS ∈ {1, 4}`) and the parallel linalg substrate
//!   (row-parallel `syrk_upper`/`gemm_tn`, RHS-column-parallel
//!   triangular solves) must produce bit-identical results at any
//!   thread count, across a `ntile` sweep;
//! * **shared vs per-layer factorization** — a `FactoredSystem` built
//!   once per tap group must yield exactly the codes the solver produces
//!   when it rebuilds the factor itself, with and without `act_order`,
//!   for both the OJBKQ family and the GPTQ baseline.
//!
//! The thread count is process-global, so every test that flips it goes
//! through [`with_threads`], which uses the programmatic
//! [`ojbkq::parallel::set_thread_override`] pin (NOT `env::set_var`,
//! whose glibc `setenv` races concurrent `env::var` reads from other
//! test threads) and is serialized by a file-wide mutex.

use ojbkq::coordinator::quantize_model;
use ojbkq::data::SyntheticGrammar;
use ojbkq::linalg::{cholesky_upper, gemm_tn, solve_lower_t, solve_upper_mat, syrk_upper};
use ojbkq::model::{LanguageModel, Model};
use ojbkq::parallel::set_thread_override;
use ojbkq::quant::{
    gptq, ojbkq as ojbkq_solver, quantize_layer, quantize_layer_shared, FactoredSystem, Method,
    QuantConfig,
};
use ojbkq::rng::Rng;
use ojbkq::tensor::Matrix;
use std::sync::Mutex;

static PIN_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the worker thread count pinned to `n`, clearing the pin
/// afterwards. Serialized across tests in this binary.
fn with_threads<T>(n: usize, f: impl FnOnce() -> T) -> T {
    let _guard = PIN_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    set_thread_override(n);
    let out = f();
    set_thread_override(0);
    out
}

fn layer(m: usize, n: usize, p: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    let x_fp = Matrix::randn(p, m, 1.0, &mut rng);
    let noise = Matrix::randn(p, m, 0.05, &mut rng);
    let x_rt = x_fp.add(&noise);
    (w, x_fp, x_rt)
}

#[test]
fn decode_bit_identical_across_thread_counts_and_ntiles() {
    let (w, x_fp, x_rt) = layer(48, 40, 96, 0xD1);
    for act_order in [false, true] {
        for &ntile in &[5usize, 16, 40, 64] {
            let cfg = QuantConfig {
                wbit: 3,
                group_size: 16,
                k: 5,
                ntile,
                mu: 0.5,
                lambda: 0.3,
                act_order,
                ..Default::default()
            };
            let solve = |threads: usize| {
                with_threads(threads, || {
                    let mut rng = Rng::new(7);
                    ojbkq_solver::quantize(&w, &x_fp, &x_rt, &cfg, &mut rng, None).unwrap()
                })
            };
            let serial = solve(1);
            let parallel = solve(4);
            assert_eq!(
                serial.codes, parallel.codes,
                "codes diverged: act_order={act_order} ntile={ntile}"
            );
            assert_eq!(
                serial.dequantize().as_slice(),
                parallel.dequantize().as_slice(),
                "effective weight diverged: act_order={act_order} ntile={ntile}"
            );
        }
    }
}

#[test]
fn shared_factor_matches_per_layer_ojbkq() {
    let (w, x_fp, x_rt) = layer(32, 28, 64, 0xD2);
    for act_order in [false, true] {
        for method in [
            Method::Ojbkq,
            Method::BabaiNaive,
            Method::KleinRandomK,
            Method::Qep,
            Method::QuantEase,
            Method::AdmmQ,
        ] {
            let cfg = QuantConfig {
                wbit: 4,
                group_size: 8,
                k: 3,
                ntile: 12,
                mu: 0.4,
                lambda: 0.25,
                act_order,
                ..Default::default()
            };
            let shared = FactoredSystem::for_method(method, &x_rt, &cfg)
                .unwrap()
                .expect("ojbkq-family methods factorize");
            let (q_shared, _) = quantize_layer_shared(
                method,
                &w,
                &x_fp,
                &x_rt,
                &cfg,
                11,
                None,
                Some(&shared),
            )
            .unwrap();
            let (q_solo, _) = quantize_layer(method, &w, &x_fp, &x_rt, &cfg, 11, None).unwrap();
            assert_eq!(
                q_shared.codes, q_solo.codes,
                "{method:?} act_order={act_order}: shared factor changed codes"
            );
            assert_eq!(
                q_shared.dequantize().as_slice(),
                q_solo.dequantize().as_slice(),
                "{method:?} act_order={act_order}: shared factor changed weights"
            );
            assert_eq!(q_shared.perm, q_solo.perm);
        }
    }
}

#[test]
fn shared_factor_matches_per_layer_gptq() {
    let (w, _x_fp, x_rt) = layer(40, 24, 80, 0xD3);
    for act_order in [false, true] {
        let cfg = QuantConfig { wbit: 3, group_size: 8, act_order, ..Default::default() };
        let shared = FactoredSystem::for_method(Method::Gptq, &x_rt, &cfg)
            .unwrap()
            .expect("gptq factorizes");
        let q_shared = gptq::quantize_with(&w, &x_rt, &cfg, Some(&shared)).unwrap();
        let q_solo = gptq::quantize(&w, &x_rt, &cfg).unwrap();
        assert_eq!(q_shared.codes, q_solo.codes, "act_order={act_order}");
        assert_eq!(
            q_shared.dequantize().as_slice(),
            q_solo.dequantize().as_slice(),
            "act_order={act_order}"
        );
        assert_eq!(q_shared.perm, q_solo.perm);
    }
}

#[test]
fn mismatched_shared_factor_is_rejected() {
    let (w, x_fp, x_rt) = layer(24, 16, 48, 0xD4);
    let cfg = QuantConfig::default();
    // Family mismatch: a GPTQ factor handed to the OJBKQ solver.
    let gptq_sys = FactoredSystem::for_method(Method::Gptq, &x_rt, &cfg).unwrap().unwrap();
    let mut rng = Rng::new(1);
    assert!(ojbkq_solver::quantize_with(&w, &x_fp, &x_rt, &cfg, &mut rng, None, Some(&gptq_sys))
        .is_err());
    // Dimension mismatch: factor built for another layer width.
    let (_, _, x_other) = layer(20, 16, 48, 0xD5);
    let wrong_dim = FactoredSystem::for_method(Method::Gptq, &x_other, &cfg).unwrap().unwrap();
    assert!(gptq::quantize_with(&w, &x_rt, &cfg, Some(&wrong_dim)).is_err());
    // Requirements mismatch within one family: a lean OJBKQ factor (R
    // only) handed to the iterative solvers, which need the full Gram
    // resident. Silently accepting it would make QuantEase/ADMM-Q refine
    // against the wrong quadratic — it must be a hard error instead.
    let lean = FactoredSystem::for_method(Method::Ojbkq, &x_rt, &cfg).unwrap().unwrap();
    for method in [Method::QuantEase, Method::AdmmQ] {
        let err = quantize_layer_shared(method, &w, &x_fp, &x_rt, &cfg, 11, None, Some(&lean))
            .expect_err("lean factor must be rejected by the gram-requiring families");
        assert!(
            format!("{err:#}").contains("Gram"),
            "{method:?}: rejection should name the missing Gram requirement, got: {err:#}"
        );
    }
}

#[test]
fn linalg_substrate_bit_identical_across_threads() {
    let mut rng = Rng::new(0xD6);
    // Large enough to cross every parallel threshold: syrk needs
    // p·m² ≥ 2²² (512·96² ≈ 4.7M), gemm_tn 2·p·m·n ≥ 2²² (≈ 25M), and
    // the triangular solves n²·nrhs ≥ 2²¹ (96²·256 ≈ 2.4M) — so the
    // T=4 leg genuinely exercises solve_cols_par, not the serial path.
    let x = Matrix::randn(512, 96, 1.0, &mut rng);
    let b = Matrix::randn(512, 256, 1.0, &mut rng);
    let rhs = Matrix::randn(96, 256, 1.0, &mut rng);
    let run = |threads: usize| {
        with_threads(threads, || {
            let g = syrk_upper(&x, 0.5);
            let c = gemm_tn(&x, &b);
            let r = cholesky_upper(&g).unwrap();
            let u = solve_lower_t(&r, &rhs);
            let v = solve_upper_mat(&r, &u);
            (g, c, u, v)
        })
    };
    let (g1, c1, u1, v1) = run(1);
    let (g4, c4, u4, v4) = run(4);
    assert_eq!(g1.as_slice(), g4.as_slice(), "syrk_upper");
    assert_eq!(c1.as_slice(), c4.as_slice(), "gemm_tn");
    assert_eq!(u1.as_slice(), u4.as_slice(), "solve_lower_t");
    assert_eq!(v1.as_slice(), v4.as_slice(), "solve_upper_mat");
}

#[test]
fn pipeline_bit_identical_across_thread_counts() {
    // End-to-end: the full pipeline (captures through the packed engine,
    // shared group factors, parallel tile decode) must produce the same
    // quantized model at any thread count.
    let cfg_model = ojbkq::config::ModelConfig {
        name: "t".into(),
        vocab_size: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
    };
    let mut rng = Rng::new(3);
    let model = Model::random(cfg_model, &mut rng);
    let corpus = SyntheticGrammar::new(32, 0.2, 5).corpus(6_000, &mut rng);
    let cfg = QuantConfig { wbit: 4, group_size: 8, k: 3, ntile: 8, ..Default::default() };
    let run = |threads: usize| {
        with_threads(threads, || {
            let (qm, _) = quantize_model(&model, &corpus, Method::Ojbkq, &cfg, 3, 16, None)
                .unwrap();
            qm
        })
    };
    let qm1 = run(1);
    let qm4 = run(4);
    let toks: Vec<u16> = vec![2, 4, 6, 8, 1];
    let y1 = qm1.forward(&toks);
    let y4 = qm4.forward(&toks);
    assert_eq!(y1.as_slice(), y4.as_slice(), "pipeline output diverged across threads");
}
