//! Property-based tests over the solver invariants (own tiny
//! proptest-style runner, `ojbkq::testutil`), exercising random BILS
//! instances across dimensions, bit-widths and conditioning regimes.

use ojbkq::quant::babai::{decode_greedy, residual_sq};
use ojbkq::quant::klein::{alpha_for, decode_kbest, decode_sampled_with_uniforms, solve_rho};
use ojbkq::quant::ppi::{decode_tile, PpiInput};
use ojbkq::quant::rtn::round_code;
use ojbkq::rng::Rng;
use ojbkq::tensor::Matrix;
use ojbkq::testutil::{check_cases, gen_dim, gen_solver_case};

/// Babai's per-step optimality: flipping any single coordinate of the
/// greedy decode by ±1 (staying in the box) never reduces the residual of
/// the *suffix* problem it was chosen for. We check the global residual
/// against single-coordinate perturbations of the LAST decoded row
/// (row 0 is decoded last and its center is conditioned on all others),
/// where single-flip optimality does hold globally.
#[test]
fn prop_babai_last_row_flip_never_helps() {
    check_cases(0xA1, 40, |rng, _| {
        let m = gen_dim(rng, 4, 32);
        let case = gen_solver_case(rng, m, 4);
        let q = decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
        let base = residual_sq(&case.r, &case.s, &case.qbar, &q);
        for delta in [-1.0f32, 1.0] {
            let flipped = q[0] + delta;
            if flipped < 0.0 || flipped > case.qmax {
                continue;
            }
            let mut q2 = q.clone();
            q2[0] = flipped;
            let r2 = residual_sq(&case.r, &case.s, &case.qbar, &q2);
            assert!(
                r2 + 1e-6 >= base,
                "flipping row0 by {delta} improved residual {base} -> {r2}"
            );
        }
    });
}

/// Box feasibility: every solver output is integral and inside the box,
/// for every bit-width.
#[test]
fn prop_outputs_always_feasible() {
    check_cases(0xA2, 30, |rng, _| {
        let wbit = [2u8, 3, 4][rng.below(3) as usize];
        let m = gen_dim(rng, 3, 40);
        let case = gen_solver_case(rng, m, wbit);
        let q = decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
        let alpha = alpha_for(5, m, 0.01) as f32;
        let u: Vec<f32> = rng.uniform_vec_f32(m);
        let qs = decode_sampled_with_uniforms(&case.r, &case.s, &case.qbar, case.qmax, alpha, &u);
        for v in q.iter().chain(qs.iter()) {
            assert!(v.fract() == 0.0 && *v >= 0.0 && *v <= case.qmax, "v={v}");
        }
    });
}

/// K-best residual is monotone non-increasing in K when the candidate
/// sets are nested (same RNG stream ⇒ first K candidates shared).
#[test]
fn prop_kbest_monotone_under_nesting() {
    check_cases(0xA3, 20, |rng, case_idx| {
        let m = gen_dim(rng, 4, 24);
        let case = gen_solver_case(rng, m, 4);
        let seed = 7_000 + case_idx as u64;
        // NOTE: decode_kbest recomputes alpha per K, so candidate sets are
        // not strictly nested across K; we assert the greedy floor plus
        // average-case improvement instead of strict nesting.
        let mut r1 = Rng::new(seed);
        let (_, res1) = decode_kbest(&case.r, &case.s, &case.qbar, case.qmax, 1, &mut r1);
        let greedy = decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
        let gres = residual_sq(&case.r, &case.s, &case.qbar, &greedy);
        assert!(res1 <= gres + 1e-9, "K-best lost to its own reserved greedy path");
    });
}

/// The PPI tile decoder agrees with the per-column reference solvers for
/// random tiles, any block size.
#[test]
fn prop_ppi_matches_column_reference() {
    check_cases(0xA4, 15, |rng, _| {
        let m = gen_dim(rng, 4, 28);
        let ntile = gen_dim(rng, 1, 6);
        let k = rng.below(4) as usize;
        let block = 1 + rng.below(10) as usize;
        let case = gen_solver_case(rng, m, 4);
        let s = Matrix::from_fn(m, ntile, |i, _| case.s[i]);
        let qbar = Matrix::from_fn(m, ntile, |i, _| case.qbar[i]);
        let alpha: Vec<f32> = (0..ntile).map(|_| alpha_for(k.max(2), m, 0.01) as f32).collect();
        let uniforms = rng.uniform_vec_f32((k + 1) * m * ntile);
        let out = decode_tile(&PpiInput {
            r: &case.r,
            s: &s,
            qbar: &qbar,
            qmax: case.qmax,
            k,
            block,
            alpha: &alpha,
            uniforms: &uniforms,
        });
        // All columns share identical (s, qbar) and path-0 is greedy, so
        // path-0 must equal the per-column greedy decode everywhere.
        let expect = decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
        for j in 0..ntile {
            for i in 0..m {
                let got = if out.winner[j] == 0 { out.q.get(i, j) } else { f32::NAN };
                if out.winner[j] == 0 {
                    assert_eq!(got, expect[i], "i={i} j={j}");
                }
            }
            // Winner is never worse than greedy.
            assert!(out.resid[j] <= out.path_resids.get(0, j) as f64 + 1e-6);
        }
    });
}

/// Scale invariance: multiplying all scales by a constant c rescales the
/// lattice uniformly, leaving the greedy decode unchanged (centers and
/// thresholds are scale-free in q-space).
#[test]
fn prop_greedy_scale_invariance() {
    check_cases(0xA5, 25, |rng, _| {
        let m = gen_dim(rng, 3, 24);
        let case = gen_solver_case(rng, m, 4);
        let q1 = decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
        let c = 0.25 + 3.0 * rng.uniform_f32();
        let s2: Vec<f32> = case.s.iter().map(|&v| v * c).collect();
        let q2 = decode_greedy(&case.r, &s2, &case.qbar, case.qmax);
        assert_eq!(q1, q2, "greedy decode must be invariant to uniform scale (c={c})");
    });
}

/// The rho schedule: K = (e·rho)^(2m/rho) holds at the returned root and
/// alpha is non-negative and monotone decreasing in K.
#[test]
fn prop_rho_equation_and_alpha_monotone() {
    check_cases(0xA6, 20, |rng, _| {
        let m = gen_dim(rng, 8, 512);
        let k = 2 + rng.below(60) as usize;
        let rho = solve_rho(k, m);
        if rho < 1e8 {
            let lhs = (k as f64).ln();
            let rhs = (2.0 * m as f64 / rho) * (1.0 + rho.ln());
            assert!((lhs - rhs).abs() < 1e-4, "k={k} m={m} rho={rho}");
        }
        let a_small = alpha_for(k, m, 0.05);
        let a_large = alpha_for(k + 5, m, 0.05);
        assert!(a_small >= a_large, "alpha must decrease with K");
        assert!(a_large >= 0.0);
    });
}

/// Klein sampling with u drawn uniformly hits the greedy code with
/// probability -> 1 as alpha grows (continuity between Ours(R) and
/// Ours(N)).
#[test]
fn prop_sampling_sharpens_to_greedy() {
    check_cases(0xA7, 10, |rng, _| {
        let m = gen_dim(rng, 4, 16);
        let case = gen_solver_case(rng, m, 4);
        let greedy = decode_greedy(&case.r, &case.s, &case.qbar, case.qmax);
        let trials = 20;
        let mut agree = 0;
        for t in 0..trials {
            let u: Vec<f32> = Rng::new(t as u64).uniform_vec_f32(m);
            let q = decode_sampled_with_uniforms(
                &case.r, &case.s, &case.qbar, case.qmax, 1e8, &u,
            );
            if q == greedy {
                agree += 1;
            }
        }
        assert!(agree >= trials - 1, "alpha=1e8 agreed only {agree}/{trials}");
    });
}

/// RTN's rounding helper is idempotent and monotone.
#[test]
fn prop_round_code_properties() {
    check_cases(0xA8, 50, |rng, _| {
        let qmax = [3.0f32, 7.0, 15.0][rng.below(3) as usize];
        let a = (qmax + 4.0) * rng.uniform_f32() - 2.0;
        let b = (qmax + 4.0) * rng.uniform_f32() - 2.0;
        let ra = round_code(a, qmax);
        assert_eq!(round_code(ra, qmax), ra, "idempotence");
        if a <= b {
            assert!(round_code(a, qmax) <= round_code(b, qmax), "monotonicity");
        }
    });
}
