//! Equivalence of the streaming activation-propagation engine with the
//! legacy prefix re-forward captures: the refactor must change *where*
//! activations come from (resident hidden-state caches advanced once per
//! block) without changing a single captured value — on full-precision
//! models, on partially-quantized models, and through the end-to-end
//! pipeline, bit-exactly and deterministically under parallel
//! per-sequence stepping.

use ojbkq::config::ModelConfig;
use ojbkq::coordinator::{CaptureMode, Pipeline};
use ojbkq::data::SyntheticGrammar;
use ojbkq::model::{LanguageModel, LinearId, LinearKind, Model, TapPoint, TapSet};
use ojbkq::quant::{Method, QuantConfig};
use ojbkq::rng::Rng;

fn setup() -> (Model, Vec<Vec<u16>>) {
    let cfg = ModelConfig {
        name: "stream".into(),
        vocab_size: 48,
        d_model: 24,
        n_layers: 3,
        n_heads: 2,
        d_ff: 32,
        max_seq: 32,
    };
    let mut rng = Rng::new(0x57E4);
    let model = Model::random(cfg, &mut rng);
    let corpus = SyntheticGrammar::new(48, 0.2, 7).corpus(8_000, &mut rng);
    let calib = corpus.calibration(3, 20, &mut rng);
    (model, calib)
}

/// Capture all four taps of `block` over `calib` with the legacy prefix
/// re-forward path.
fn legacy_taps(model: &Model, calib: &[Vec<u16>], block: usize) -> TapSet {
    let mut taps = TapSet::request(block, &TapPoint::all());
    for seq in calib {
        model.forward_prefix_taps(seq, &mut taps, block);
    }
    taps
}

/// Capture all four taps of `block` by streaming resident hidden states
/// through `block_step`.
fn streaming_taps(model: &Model, calib: &[Vec<u16>], block: usize) -> TapSet {
    let mut taps = TapSet::request(block, &TapPoint::all());
    for seq in calib {
        let mut hidden = model.embed_sequence(seq);
        for bi in 0..block {
            model.block_step(&mut hidden, bi, &mut TapSet::default());
        }
        model.block_step(&mut hidden, block, &mut taps);
    }
    taps
}

fn assert_taps_match(model: &Model, calib: &[Vec<u16>], label: &str) {
    for block in 0..model.blocks.len() {
        let mut legacy = legacy_taps(model, calib, block);
        let mut streaming = streaming_taps(model, calib, block);
        for p in TapPoint::all() {
            let a = legacy.take(block, p).expect("legacy tap");
            let b = streaming.take(block, p).expect("streaming tap");
            assert_eq!(a.shape(), b.shape(), "{label} block {block} {p:?} shape");
            assert!(
                b.rel_err(&a) < 1e-6,
                "{label} block {block} {p:?}: rel err {}",
                b.rel_err(&a)
            );
        }
    }
}

#[test]
fn streaming_taps_match_legacy_on_fp_model() {
    let (model, calib) = setup();
    assert_taps_match(&model, &calib, "fp");
}

#[test]
fn streaming_taps_match_legacy_on_partially_quantized_model() {
    let (model, calib) = setup();
    // Fake-quantize the full first block + the attention half of the
    // second (a mid-pipeline prefix state) so the resident runtime cache
    // must flow through genuinely modified weights.
    let mut pq = model.clone();
    let coarse = |w: &ojbkq::tensor::Matrix| w.map(|v| (v * 8.0).round() / 8.0);
    for &kind in LinearKind::all() {
        let id = LinearId { block: 0, kind };
        pq.set_linear(id, coarse(pq.linear(id)));
    }
    for kind in [LinearKind::Q, LinearKind::K, LinearKind::V, LinearKind::O] {
        let id = LinearId { block: 1, kind };
        pq.set_linear(id, coarse(pq.linear(id)));
    }
    assert_taps_match(&pq, &calib, "partially-quantized");
}

#[test]
fn pipeline_streaming_matches_reforward() {
    let (model, calib) = setup();
    // Dense execution on both legs: this test isolates the *capture
    // strategy* (streaming vs prefix re-forward), and the re-forward path
    // always captures from the dense spliced mirror. Packed-vs-dense
    // execution parity is covered by `tests/packed_infer.rs`.
    let cfg = QuantConfig {
        wbit: 4,
        group_size: 8,
        k: 2,
        ntile: 16,
        mu: 0.3,
        lambda: 0.2,
        packed_exec: false,
        ..Default::default()
    };
    let (qm_stream, rep_stream) =
        Pipeline::new(&model, calib.clone(), Method::Ojbkq, cfg.clone(), None)
            .run()
            .unwrap();
    let (qm_legacy, rep_legacy) = Pipeline::new(&model, calib, Method::Ojbkq, cfg, None)
        .with_capture_mode(CaptureMode::Reforward)
        .run()
        .unwrap();
    // Identical captures + deterministic solver => identical models.
    let toks: Vec<u16> = vec![1, 7, 13, 2, 40];
    assert!(
        qm_stream.forward(&toks).rel_err(&qm_legacy.forward(&toks)) < 1e-9,
        "streaming and re-forward pipelines must produce equivalent models"
    );
    assert_eq!(rep_stream.layers.len(), rep_legacy.layers.len());
    for (a, b) in rep_stream.layers.iter().zip(rep_legacy.layers.iter()) {
        assert_eq!(a.id, b.id);
        let denom = b.stats.rt_err.abs().max(1e-12);
        assert!(
            (a.stats.rt_err - b.stats.rt_err).abs() / denom < 1e-6,
            "{}: rt_err {} vs {}",
            a.id,
            a.stats.rt_err,
            b.stats.rt_err
        );
    }
    // The whole point: streaming advances each cache once per block.
    assert!(rep_stream.capture_block_steps < rep_legacy.capture_block_steps);
}

#[test]
fn streaming_pipeline_deterministic_under_parallel_stepping() {
    let (model, calib) = setup();
    let cfg = QuantConfig { wbit: 4, group_size: 8, k: 3, ntile: 8, ..Default::default() };
    let (qa, ra) = Pipeline::new(&model, calib.clone(), Method::Ojbkq, cfg.clone(), None)
        .run()
        .unwrap();
    let (qb, rb) = Pipeline::new(&model, calib, Method::Ojbkq, cfg, None).run().unwrap();
    let toks: Vec<u16> = vec![2, 4, 6, 8, 10];
    // Bit-exact: parallel per-sequence stepping must not perturb order of
    // accumulation anywhere (results are stacked in sequence order).
    assert!(qa.forward(&toks).rel_err(&qb.forward(&toks)) < 1e-12);
    for (a, b) in ra.layers.iter().zip(rb.layers.iter()) {
        assert_eq!(a.stats.rt_err, b.stats.rt_err, "{}", a.id);
        assert_eq!(a.stats.jta_err, b.stats.jta_err, "{}", a.id);
    }
}

/// The O(n_blocks) capture-count guarantee on a deeper model: block
/// advances grow linearly with depth (2 per block per sequence), not
/// quadratically.
#[test]
fn capture_block_steps_scale_linearly_with_depth() {
    let mut steps = Vec::new();
    for n_layers in [2usize, 4] {
        let cfg = ModelConfig {
            name: format!("d{n_layers}"),
            vocab_size: 32,
            d_model: 16,
            n_layers,
            n_heads: 2,
            d_ff: 24,
            max_seq: 32,
        };
        let mut rng = Rng::new(5);
        let model = Model::random(cfg, &mut rng);
        let corpus = SyntheticGrammar::new(32, 0.2, 3).corpus(6_000, &mut rng);
        let calib = corpus.calibration(2, 16, &mut rng);
        let qcfg = QuantConfig { wbit: 4, group_size: 8, ..Default::default() };
        let (_, rep) = Pipeline::new(&model, calib, Method::Rtn, qcfg, None).run().unwrap();
        assert_eq!(rep.capture_block_steps, 2 * 2 * n_layers as u64);
        steps.push(rep.capture_block_steps);
    }
    // Doubling depth exactly doubles capture cost.
    assert_eq!(steps[1], 2 * steps[0]);
}
